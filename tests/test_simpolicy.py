"""Simulation-assisted selection (SimAS-style): SimPolicy / SimAssistedHybrid.

The core contract: on a noise-free cell the policy's argmin over the priced
candidate set must equal the Oracle selector's choice — on BOTH simulation
backends — and the sim-pruned hybrid's exploration set must always be a
subset of the full portfolio containing the Oracle pick.  Wiring tests cover
the three execution layers (campaign lanes, dispatch waves, step plans) and
the ``REPRO_SIM_POLICY`` environment selection.
"""

import os
import sys

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from _hypothesis_fallback import given, settings, st

from repro.core import (N_ALGORITHMS, Candidate, OraclePolicy,
                        SelectionService, SimAssistedHybrid, SimPolicy,
                        SimUnavailable, SIM_POLICY_ENV, is_sim_policy,
                        make_policy, resolve_sim_policy)
from repro.core.api import Observation
from repro.sim import LoopWhatIf, get_application, get_backend, get_system
from repro.sim.backends import InstanceSpec
from repro.sim.whatif import noise_free

BACKENDS = ("python", "jax")


# ---------------------------------------------------------------------------
# stub candidate simulator (unit-level tests)
# ---------------------------------------------------------------------------

class StubSim:
    """Deterministic candidate simulator over a fixed cost vector."""

    def __init__(self, costs, ready: bool = True):
        self.costs = np.asarray(costs, dtype=np.float64)
        self.ready = ready
        self.calls = 0

    def candidates(self):
        return [Candidate(a) for a in range(len(self.costs))]

    def price(self, cands):
        if not self.ready:
            raise SimUnavailable("stub not ready")
        self.calls += 1
        return [Observation(loop_time=float(self.costs[c.alg]))
                for c in cands]


# ---------------------------------------------------------------------------
# the oracle-agreement contract (acceptance criterion)
# ---------------------------------------------------------------------------

def _oracle_choice(profile, system, backend, candidates):
    """Test-local Oracle: exhaustively evaluate every candidate on the
    noise-free system (independent spec construction / seeds from
    ``LoopWhatIf``) and wrap the argmin as the paper's OraclePolicy."""
    bk = get_backend(backend)
    specs = [InstanceSpec(profile_id=0, alg=c.alg,
                          chunk_param=0 if c.chunk_param is None
                          else c.chunk_param,
                          seed=(7, i))
             for i, c in enumerate(candidates)]
    res = bk.run_batch([profile], noise_free(system), specs)
    best = int(np.argmin(res.loop_time))
    oracle = OraclePolicy(lambda t: candidates[best].alg)
    return oracle.decide().action, candidates[best], res.loop_time


@pytest.mark.parametrize("backend", BACKENDS)
def test_simpolicy_argmin_matches_oracle_noise_free(backend):
    """On a noise-free cell the SimPolicy decision IS the Oracle choice
    (tc/epyc: the winner leads the runner-up by ~48 %, so seed details of
    the closed-form tails cannot flip the argmin)."""
    profile = get_application("tc").loops(0)[0]
    system = get_system("epyc")
    whatif = LoopWhatIf(system, backend=backend)
    whatif.set_context(profile, 0)
    cands = whatif.candidates()

    oracle_action, oracle_cand, times = _oracle_choice(
        profile, system, backend, cands)
    policy = SimPolicy(whatif, reward="LT")
    d = policy.decide()

    assert d.phase == "exploit"
    assert d.action == oracle_action
    assert d.chunk_param == oracle_cand.chunk_param
    # the margin that makes this cell a meaningful probe
    spread = np.partition(times, 1)
    assert (spread[1] - spread[0]) / spread[0] > 0.2


def test_simpolicy_backends_agree():
    """Both engines elect the same candidate on the well-separated cell."""
    profile = get_application("tc").loops(0)[0]
    system = get_system("epyc")
    picks = []
    for backend in BACKENDS:
        whatif = LoopWhatIf(system, backend=backend)
        whatif.set_context(profile, 0)
        d = SimPolicy(whatif).decide()
        picks.append((d.action, d.chunk_param))
    assert picks[0] == picks[1]


def test_whatif_cache_distinguishes_equal_total_profiles():
    """Regression: mean-normalized patterns share N*unit totals across time
    steps, so the price cache must key on prefix-grid *content* — sphynx
    steps with equal totals but different load distributions previously
    aliased to one cached candidate table (stale argmin)."""
    app = get_application("sphynx")
    system = get_system("epyc")
    p6, p13 = app.loops(6)[0], app.loops(13)[0]
    assert p6.total == p13.total            # the collision precondition
    assert not np.array_equal(p6.prefix_grid, p13.prefix_grid)
    whatif = LoopWhatIf(system)
    whatif.set_context(p6, 0)
    a = whatif.price(whatif.candidates())
    whatif.set_context(p13, 0)
    b = whatif.price(whatif.candidates())
    assert a is not b                       # no stale cache hit
    fresh = LoopWhatIf(system)
    fresh.set_context(p13, 0)
    c = fresh.price(fresh.candidates())
    assert [o.loop_time for o in b] == [o.loop_time for o in c]


def test_simpolicy_pricing_is_deterministic_and_cached():
    profile = get_application("mandelbrot").loops(3)[1]
    system = get_system("broadwell")
    whatif = LoopWhatIf(system)
    whatif.set_context(profile, 0)
    cands = whatif.candidates()
    a = whatif.price(cands)
    b = whatif.price(cands)
    assert a is b                        # cache hit
    fresh = LoopWhatIf(system)
    fresh.set_context(profile, 0)
    c = fresh.price(cands)
    assert [o.loop_time for o in a] == [o.loop_time for o in c]


# ---------------------------------------------------------------------------
# fallback behavior
# ---------------------------------------------------------------------------

def test_simpolicy_flat_spread_falls_back_to_expert():
    policy = SimPolicy(StubSim(np.full(12, 3.0)), confidence_threshold=0.02)
    d = policy.decide()
    assert d.phase == "expert"
    # separated costs: committed argmin
    policy = SimPolicy(StubSim([5.0] * 11 + [1.0]))
    d = policy.decide()
    assert d.phase == "exploit" and d.action == 11


def test_simpolicy_unready_simulator_falls_back_to_expert():
    sim = StubSim(np.arange(12), ready=False)
    policy = SimPolicy(sim)
    d = policy.decide()
    assert d.phase == "expert" and d.confidence == 0.0
    # LoopWhatIf with no context behaves identically
    whatif = LoopWhatIf(get_system("broadwell"))
    with pytest.raises(SimUnavailable):
        whatif.price([Candidate(0)])
    assert SimPolicy(whatif).decide().phase == "expert"


def test_simpolicy_feedback_tracks_prediction_fidelity():
    policy = SimPolicy(StubSim([5.0] * 11 + [1.0]))
    d = policy.decide()
    policy.feedback(d, Observation(loop_time=1.1, lib=2.0))
    assert policy.pred_log == [(1.0, 1.1)]


# ---------------------------------------------------------------------------
# SimAssistedHybrid: the pruned-window property (acceptance criterion)
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(costs=st.lists(st.floats(min_value=0.01, max_value=100.0,
                                allow_nan=False, allow_infinity=False),
                      min_size=12, max_size=12),
       top_k=st.integers(min_value=1, max_value=12))
def test_simhybrid_pruned_set_is_subset_containing_oracle_pick(costs, top_k):
    sim = StubSim(costs)
    policy = SimAssistedHybrid(sim, top_k=top_k, expert_steps=1)
    d = policy.decide()                          # expert phase
    policy.feedback(d, Observation(loop_time=1.0, lib=5.0))
    d = policy.decide()                          # builds the pruned agent
    assert policy.agent is not None
    actions = set(policy.actions)
    assert actions <= set(range(N_ALGORITHMS))   # subset of the full grid
    assert len(actions) == top_k
    assert int(np.argmin(costs)) in actions      # contains the Oracle pick
    assert d.action in actions                   # RL explores only the top-k


def test_simhybrid_learning_budget_shrinks():
    sim = StubSim(np.arange(12, dtype=float))
    policy = SimAssistedHybrid(sim, top_k=3, expert_steps=2)
    assert policy.learning_steps == 2 + 9        # vs Hybrid's 31, QLearn 144+
    hybrid = make_policy("Hybrid")
    assert policy.learning_steps < hybrid.learning_steps


def test_simhybrid_unready_simulator_uses_expert_window():
    sim = StubSim(np.arange(12, dtype=float), ready=False)
    policy = SimAssistedHybrid(sim, top_k=4, expert_steps=1)
    d = policy.decide()
    policy.feedback(d, Observation(loop_time=1.0, lib=5.0))
    policy.decide()
    # falls back to HybridPolicy's expert-centered window
    assert len(policy.actions) == policy.window
    assert set(policy.actions) <= set(range(N_ALGORITHMS))


# ---------------------------------------------------------------------------
# campaign-lane wiring
# ---------------------------------------------------------------------------

def test_sim_lanes_lockstep_equals_sequential():
    """SimPolicy/SimHybrid lanes ride the lockstep replay bit-exactly:
    candidate pricing draws from a stateless seed, never from the lane rng."""
    from repro.sim import CellSpec, ReplayBatch, run_selector_sequential

    lanes = [CellSpec("mandelbrot", "broadwell", "SimPolicy", "default", "LT"),
             CellSpec("mandelbrot", "broadwell", "SimHybrid", "default", "LT"),
             CellSpec("mandelbrot", "broadwell", "QLearn", "default", "LT")]
    batch = ReplayBatch(lanes, T=3).run()
    for spec, run in zip(lanes, batch):
        ref = run_selector_sequential("mandelbrot", "broadwell",
                                      spec.selector, spec.chunk_mode,
                                      reward=spec.reward, T=3)
        assert run.total == ref.total
        assert run.history == ref.history


def test_sim_lane_decisions_follow_the_sim_winner():
    """A SimPolicy lane executes the simulator's per-loop winners (phase
    'exploit' from instance 0 — no live exploration)."""
    from repro.sim import run_selector

    run = run_selector("tc", "epyc", "SimPolicy", reward="LT", T=3)
    profile = get_application("tc").loops(0)[0]
    whatif = LoopWhatIf(get_system("epyc"))
    whatif.set_context(profile, 0)
    d = SimPolicy(whatif).decide()
    assert [a for a, _, _ in run.history["L0"]] == [d.action] * 3


# ---------------------------------------------------------------------------
# serving + autotuner wiring
# ---------------------------------------------------------------------------

def _requests(n=192, seed=0):
    from repro.data.pipeline import Request
    rng = np.random.default_rng(seed)
    return [Request(rid=i, prompt_len=int(rng.integers(10, 400)),
                    gen_len=int(rng.integers(10, 200)), arrival=0.0)
            for i in range(n)]


def test_dispatch_simulator_simpolicy_executes_what_if_argmin():
    from repro.serving.engine import DispatchSimulator

    sim = DispatchSimulator(n_replicas=8, selector="SimPolicy")
    reqs = _requests()
    predicted = sim.what_if(reqs)            # default-chunk candidate slice
    st0 = sim.run_wave(reqs)
    # the committed wave is the argmin over the *full* candidate set; its
    # makespan can only improve on the default-chunk argmin
    assert st0.makespan <= predicted.min() * (1 + 1e-9)
    d = sim.service.history("dispatch")
    assert len(d) == 1


def test_dispatch_simulator_binds_caller_supplied_wave_pricer():
    """Regression: a simulator passed through selector_kw must still get
    set_requests before every wave — otherwise SimPolicy silently degrades
    to the expert fallback forever."""
    from repro.serving.engine import DispatchSimulator, WaveWhatIf

    class RecordingWaveWhatIf(WaveWhatIf):
        bound = 0

        def set_requests(self, requests):
            type(self).bound += 1
            super().set_requests(requests)

    sim = DispatchSimulator(n_replicas=8, selector="SimPolicy")
    external = RecordingWaveWhatIf(sim)
    sim2 = DispatchSimulator(n_replicas=8, selector="SimPolicy",
                             selector_kw={"simulator": external})
    external._sim = sim2
    assert sim2._whatif is external
    sim2.run_wave(_requests(64))
    assert RecordingWaveWhatIf.bound == 1
    assert sim2.service.policy("dispatch").pred_log  # sim-driven, not expert


def test_wave_whatif_candidates_cover_chunk_variants():
    from repro.serving.engine import DispatchSimulator, WaveWhatIf

    sim = DispatchSimulator(n_replicas=8, selector="Fixed",
                            selector_kw={"algorithm": 2})
    wi = WaveWhatIf(sim)
    with pytest.raises(SimUnavailable):
        wi.candidates()
    reqs = _requests(64)
    wi.set_requests(reqs)
    cands = wi.candidates()
    assert len(cands) == 24                  # 12 algs x {default, expChunk}
    priced = wi.price(cands)
    assert len(priced) == 24
    assert all(o.loop_time > 0 for o in priced)


def test_step_autotuner_simpolicy_compiles_only_the_winner():
    from repro.distributed.autotune import (DEFAULT_PLANS, PlanWhatIf,
                                            StepAutoTuner)

    built = []

    def build(plan):
        built.append(plan.name)
        return lambda *a: np.zeros(1)

    tuner = StepAutoTuner(list(DEFAULT_PLANS), build, method="SimPolicy")
    assert isinstance(tuner.sim_model, PlanWhatIf)
    for _ in range(4):
        tuner.step()
    # retuning epochs priced the portfolio in simulation: only the predicted
    # winner was ever compiled or executed live
    assert built == ["mb1_noremat"]
    assert tuner.sim_model._scale is not None


def test_plan_whatif_prior_ordering_and_calibration():
    from repro.distributed.autotune import DEFAULT_PLANS, PlanWhatIf

    m = PlanWhatIf(list(DEFAULT_PLANS))
    priors = {p.name: m.prior(p) for p in DEFAULT_PLANS}
    assert priors["mb1_noremat"] < priors["mb1_remat"]    # remat costs FLOPs
    assert priors["mb1_remat"] < priors["mb4_remat"]      # mb overhead
    m.observe(3, 2.0)                    # mb1_noremat measured at 2 s
    priced = m.price(m.candidates())
    assert priced[3].loop_time == pytest.approx(2.0)
    # unobserved plans scale by the calibrated seconds-per-unit
    assert priced[0].loop_time == pytest.approx(
        2.0 / m.prior(DEFAULT_PLANS[3]) * m.prior(DEFAULT_PLANS[0]))


# ---------------------------------------------------------------------------
# REPRO_SIM_POLICY environment selection
# ---------------------------------------------------------------------------

def test_resolve_sim_policy_env(monkeypatch):
    monkeypatch.delenv(SIM_POLICY_ENV, raising=False)
    assert resolve_sim_policy("QLearn") == "QLearn"
    monkeypatch.setenv(SIM_POLICY_ENV, "simhybrid")
    assert resolve_sim_policy("QLearn") == "SimHybrid"
    assert is_sim_policy("SimPolicy") and is_sim_policy("sim-hybrid")
    assert not is_sim_policy("QLearn") and not is_sim_policy(None)
    # a typo'd env value must fail at resolve time, naming the variable
    monkeypatch.setenv(SIM_POLICY_ENV, "SimPolcy")
    with pytest.raises(ValueError, match=SIM_POLICY_ENV):
        resolve_sim_policy("QLearn")


def test_selection_service_defaults_from_env(monkeypatch):
    monkeypatch.setenv(SIM_POLICY_ENV, "SimPolicy")
    service = SelectionService(simulator=StubSim([5.0] * 11 + [1.0]))
    policy = service.policy("r0")
    assert isinstance(policy, SimPolicy)
    assert policy.decide().action == 11
    # explicit methods always win over the env
    service = SelectionService("QLearn", reward="LT", seed=0)
    assert service.policy("r0").name == "QLearn"


def test_dispatch_simulator_defaults_from_env(monkeypatch):
    from repro.serving.engine import DispatchSimulator

    monkeypatch.setenv(SIM_POLICY_ENV, "SimPolicy")
    sim = DispatchSimulator(n_replicas=4)
    assert sim._whatif is not None
    assert isinstance(sim.service.policy("dispatch"), SimPolicy)
    monkeypatch.delenv(SIM_POLICY_ENV)
    sim = DispatchSimulator(n_replicas=4)
    assert sim._whatif is None
    assert sim.service.policy("dispatch").name == "QLearn"
