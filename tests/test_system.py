"""End-to-end system tests: fault-tolerant training (restart equivalence),
checkpointing (atomicity, elasticity), autotuning, serving dispatch, data
determinism."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, smoke_reduce
from repro.data import DataConfig, TokenPipeline, synthetic_requests
from repro.distributed import (EFCompressor, ExecutionPlan, StepAutoTuner,
                               make_plan_builder)
from repro.launch.steps import make_train_step
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.runtime import Trainer, TrainerConfig
from repro.serving import DispatchSimulator

CFG = dataclasses.replace(smoke_reduce(get_config("llama3.2-3b")),
                          vocab_size=128)
OPT = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)
DATA = DataConfig(vocab_size=128, seq_len=16, global_batch=4, seed=3)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_resume():
    p1, p2 = TokenPipeline(DATA), TokenPipeline(DATA)
    b17 = p1.batch_at(17)
    again = p2.batch_at(17)
    np.testing.assert_array_equal(b17["tokens"], again["tokens"])
    assert b17["tokens"].shape == (4, 16)
    assert (b17["tokens"] < 128).all() and (b17["tokens"] >= 0).all()
    assert not np.array_equal(p1.batch_at(0)["tokens"],
                              p1.batch_at(1)["tokens"])


def test_requests_heavy_tailed():
    reqs = synthetic_requests(500, seed=1)
    lens = np.array([r.prompt_len for r in reqs])
    assert lens.max() > 5 * np.median(lens)      # the imbalance source
    arr = np.array([r.arrival for r in reqs])
    assert (np.diff(arr) >= 0).all()


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
            "b": {"c": jnp.ones((4,), jnp.float32)}}
    for step in (5, 10, 15):
        mgr.save(step, tree)
    assert mgr.all_steps() == [10, 15]           # GC keeps newest 2
    out = mgr.restore(15, tree)
    np.testing.assert_array_equal(np.asarray(out["a"], np.float32),
                                  np.asarray(tree["a"], np.float32))
    assert out["a"].dtype == jnp.bfloat16


def test_checkpoint_async_and_atomic(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.ones((128, 128))}
    mgr.async_save(1, tree)
    mgr.wait()
    assert mgr.latest_step() == 1
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_checkpoint_async_error_surfaces_in_wait(tmp_path):
    """A background save that throws must re-raise from wait(), not vanish
    with the daemon thread (a silently lost checkpoint defeats the whole
    point of checkpointing)."""
    mgr = CheckpointManager(str(tmp_path))
    # an object-dtype leaf makes np.save(allow_pickle=False) raise on the
    # background thread
    mgr.async_save(1, {"bad": np.array([object()])})
    with pytest.raises(ValueError):
        mgr.wait()
    mgr.wait()                          # error is raised once, then cleared
    assert mgr.latest_step() is None    # nothing was committed


def test_checkpoint_async_error_surfaces_in_next_save(tmp_path):
    """Callers that never wait() still see the failure: the NEXT save (sync
    or async) joins the background thread first and re-raises."""
    good = {"w": jnp.ones((4,))}
    mgr = CheckpointManager(str(tmp_path))
    mgr.async_save(1, {"bad": np.array([object()])})
    with pytest.raises(ValueError):
        mgr.save(2, good)
    mgr2 = CheckpointManager(str(tmp_path))
    mgr2.async_save(3, {"bad": np.array([object()])})
    with pytest.raises(ValueError):
        mgr2.async_save(4, good)
    # the manager stays usable after the error surfaced
    mgr.save(5, good)
    assert mgr.latest_step() == 5


def test_checkpoint_elastic_restore(tmp_path):
    """Restore places shards with the *current* mesh's sharding (here the
    1-CPU mesh; the multi-device path is exercised in the dry-run)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(3, tree)
    sh = {"w": NamedSharding(mesh, P("data", "model"))}
    out = mgr.restore(3, tree, shardings=sh)
    assert out["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))


# ---------------------------------------------------------------------------
# fault-tolerant trainer: restart equivalence
# ---------------------------------------------------------------------------

def _run(tmp, failure_rate, n=12, seed=0):
    step = make_train_step(CFG, OPT)
    tr = Trainer(CFG, OPT, DATA,
                 TrainerConfig(ckpt_dir=str(tmp), ckpt_every=4,
                               async_ckpt=False, failure_rate=failure_rate,
                               failure_seed=6),
                 step_fn=step, seed=seed)
    return tr.train(n)


def test_restart_equivalence(tmp_path):
    """A run with injected node failures reaches the SAME final parameters
    as an uninterrupted run (deterministic data + checkpoint replay)."""
    clean = _run(tmp_path / "clean", failure_rate=0.0)
    faulty = _run(tmp_path / "faulty", failure_rate=0.15)
    assert faulty["restarts"] > 0, "failure injection never fired"
    same = jax.tree.map(
        lambda a, b: np.allclose(np.asarray(a, np.float32),
                                 np.asarray(b, np.float32), atol=1e-5),
        clean["params"], faulty["params"])
    assert all(jax.tree.leaves(same))
    assert clean["final_step"] == faulty["final_step"] == 12


def test_loss_decreases(tmp_path):
    out = _run(tmp_path, failure_rate=0.0, n=12)
    losses = out["losses"]
    assert losses[-1] < losses[0]


def test_trainer_sigterm_final_save(tmp_path):
    """SIGTERM mid-run: the loop finishes the in-flight step, the final
    synchronous save covers exactly that step (not just the last periodic
    checkpoint), and a relaunch resumes to the uninterrupted result."""
    import signal

    step_fn = make_train_step(CFG, OPT)
    tcfg = TrainerConfig(ckpt_dir=str(tmp_path / "pre"), ckpt_every=4,
                         async_ckpt=False)
    tr = Trainer(CFG, OPT, DATA, tcfg, step_fn=step_fn, seed=0)
    old = signal.getsignal(signal.SIGTERM)
    try:
        tr.install_preemption_handler()
        orig = tr.pipeline.batch_at
        calls = {"n": 0}

        def batch_at(step):
            calls["n"] += 1
            if calls["n"] == 7:            # preempt mid-step 7
                os.kill(os.getpid(), signal.SIGTERM)
            return orig(step)

        tr.pipeline.batch_at = batch_at
        out = tr.train(12)
    finally:
        signal.signal(signal.SIGTERM, old)
    assert out["preempted"] and out["final_step"] == 7
    assert tr.ckpt.latest_step() == 7      # the final save, not step 4
    tr.pipeline.batch_at = orig
    # relaunch on the same dir: replay 7..12 matches a clean 0..12 run
    tr2 = Trainer(CFG, OPT, DATA, tcfg, step_fn=step_fn, seed=0)
    resumed = tr2.train(12)
    clean = _run(tmp_path / "clean", failure_rate=0.0)
    assert not resumed["preempted"] and resumed["final_step"] == 12
    same = jax.tree.map(
        lambda a, b: np.allclose(np.asarray(a, np.float32),
                                 np.asarray(b, np.float32), atol=1e-5),
        clean["params"], resumed["params"])
    assert all(jax.tree.leaves(same))


# ---------------------------------------------------------------------------
# step-plan autotuner (the paper's technique at step granularity)
# ---------------------------------------------------------------------------

def test_autotuner_explores_then_settles():
    plans = [ExecutionPlan("mb1", microbatches=1),
             ExecutionPlan("mb2", microbatches=2),
             ExecutionPlan("mb1_noremat", microbatches=1, remat=False)]
    build = make_plan_builder(CFG, OPT)
    tuner = StepAutoTuner(plans, build, method="ExhaustiveSel")
    from repro.models import init_params
    params = init_params(CFG, jax.random.PRNGKey(0))
    opt = adamw_init(params, OPT)
    pipe = TokenPipeline(DATA)
    for step in range(8):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        (params, opt, m), plan, dt = tuner.step(params, opt, batch)
    tried = {h[0] for h in tuner.history[:3]}
    assert tried == {"mb1", "mb2", "mb1_noremat"}    # explored all plans
    settled = {h[0] for h in tuner.history[3:]}
    assert len(settled) == 1                          # then exploited one


def test_ef_compressor_preserves_signal():
    comp = EFCompressor("int8")
    g = {"w": jnp.array([1.0, -0.5, 0.25, 3.0])}
    out1 = comp(g)
    # error feedback: residual is bounded by quantization step
    err = np.asarray(g["w"] - out1["w"])
    assert np.abs(err).max() <= 3.0 / 127.0 + 1e-6
    # accumulated: applying same grad twice keeps mean error near zero
    out2 = comp(g)
    total_err = np.asarray(2 * g["w"] - (out1["w"] + out2["w"]))
    assert np.abs(total_err).max() <= 3.0 / 127.0 + 1e-6


# ---------------------------------------------------------------------------
# serving dispatch (L3)
# ---------------------------------------------------------------------------

def test_dispatch_dynamic_beats_static_on_heavy_tail():
    reqs = synthetic_requests(2048, seed=5, heavy_tail=1.1)
    static = DispatchSimulator(8, selector="Fixed",
                               selector_kw={"algorithm": 0})
    gss = DispatchSimulator(8, selector="Fixed",
                            selector_kw={"algorithm": 2})
    static.run(reqs, wave_size=256)
    gss.run(reqs, wave_size=256)
    assert gss.summary()["total_makespan"] < static.summary()["total_makespan"]
    assert gss.summary()["mean_lib"] < static.summary()["mean_lib"]


def test_dispatch_selector_converges():
    reqs = synthetic_requests(26 * 128, seed=2, heavy_tail=1.2)
    # waves are non-stationary (heavy-tailed), so damp the LIB re-trigger
    sim = DispatchSimulator(8, selector="ExhaustiveSel",
                            selector_kw={"lib_retrigger": 5.0})
    sim.run(reqs, wave_size=128)
    algs = [s.algorithm for s in sim.stats]
    assert len(set(algs[:12])) == 12          # exhaustive phase
    assert len(set(algs[12:])) <= 3           # then settles
    # the settled regime must not be a disaster.  Raw wave makespans are
    # dominated by each wave's own heavy-tailed draw, so compare against
    # the per-wave makespan lower bound (work/R vs the largest single
    # request): a normalized ratio near 1 means the selection is within
    # ExhaustiveSel's single-sample-argmin noise, not that the waves
    # happened to draw light requests
    lbs = []
    for i in range(0, len(reqs), 128):
        toks = np.array([r.prompt_len + r.gen_len for r in reqs[i:i + 128]])
        costs = sim.cost.per_token * toks + sim.cost.per_request
        lbs.append(max(costs.sum() / sim.R, costs.max()))
    ineff = np.array([s.makespan for s in sim.stats]) / np.array(lbs)
    assert ineff[12:].mean() <= ineff[:12].mean() * 1.15
